"""Streaming benchmarks: warm-start tracking value + batched-queue serving.

Two sections (both run by default; select with ``--drift`` / ``--queue``):

* **drift** — the subsystem's headline claim: on a slow-rotation stream,
  a warm-started :class:`~repro.streaming.tracker.StreamingDeEPCA`
  (resuming the tracked ``(S, W, G_prev)`` state across ticks) reaches the
  per-tick tan-theta target in measurably fewer communication rounds than
  a cold restart of the same driver from ``W0`` — communication being the
  resource DeEPCA optimizes.  Both sides run identical chunked windows on
  one persistent driver and stop at the same target, so the only
  difference is the carried state.

* **queue** — the serving claim: a ragged request mix (per-request sample
  counts and component counts) served through the dynamic-batching
  :class:`~repro.streaming.service.PCAService` rides a handful of
  compiled programs (zero *cold* launches after warm-up — the
  no-per-request-recompilation acceptance property) and beats the naive
  driver-per-request server on throughput.

``--json PATH`` exports every row (CI uploads it next to the bench_mixing
artifact); ``--quick`` shrinks shapes for smoke runs.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ConsensusEngine, IterationDriver, PowerStep,
                        erdos_renyi, metrics)
from repro.streaming import (AdmissionPolicy, DriftPolicy, PCAService,
                             SlowRotationStream, StreamingDeEPCA,
                             ragged_requests)

FULL = dict(m=8, d=64, k=4, n=48, K=5, rate=0.04, ticks=8, chunk=2,
            T_max=40, target=2e-3, requests=32, T_serve=12)
QUICK = dict(m=8, d=32, k=3, n=32, K=4, rate=0.04, ticks=4, chunk=2,
             T_max=30, target=5e-3, requests=10, T_serve=8)


# ------------------------------------------------------- drift: warm vs cold

def _cold_rounds_to_target(driver, ops, U, W0, *, chunk: int, T_max: int,
                           target: float):
    """Chunked fresh-start windows until tan-theta <= target (one driver,
    so the cold baseline also rides the jitted-program cache — the
    comparison isolates the *state*, not compilation)."""
    carry, t = None, 0
    tan = float("inf")
    while t < T_max:
        run = driver.run(ops, W0, T=chunk, t0=t, carry=carry)
        carry = run.carry
        t += chunk
        tan = float(metrics.mean_tan_theta(U, carry[1]))
        if tan <= target:
            break
    return float(driver.step.rounds * t), tan


def bench_drift(cfg, markdown: bool = True):
    m, d, k = cfg["m"], cfg["d"], cfg["k"]
    topo = erdos_renyi(m, p=0.5, seed=0)
    stream = SlowRotationStream(m=m, d=d, k=k, n_per_agent=cfg["n"],
                                rate=cfg["rate"], seed=0)
    W0 = stream.init_W0()
    chunk, target = cfg["chunk"], cfg["target"]
    max_esc = -(-cfg["T_max"] // chunk)           # enough to always hit target

    tracker = StreamingDeEPCA(
        k=k, T_tick=chunk, K=cfg["K"], topology=topo, backend="stacked",
        W0=W0, policy=DriftPolicy(target=target, escalate_T=chunk,
                                  max_escalations=max_esc))
    cold_driver = IterationDriver(
        step=PowerStep.for_algorithm("deepca", cfg["K"]),
        engine=ConsensusEngine.for_algorithm("deepca", topo, K=cfg["K"],
                                             backend="stacked"))
    rows = []
    for tick in stream.ticks(cfg["ticks"]):
        rep = tracker.tick(tick.ops, tick.U)
        cold_rounds, cold_tan = _cold_rounds_to_target(
            cold_driver, tick.ops, tick.U, W0, chunk=chunk,
            T_max=cfg["T_max"], target=target)
        rows.append({"tick": tick.t, "warm_rounds": rep.comm_rounds,
                     "warm_tan": rep.stat, "cold_rounds": cold_rounds,
                     "cold_tan": cold_tan})
    warm = float(np.mean([r["warm_rounds"] for r in rows]))
    cold = float(np.mean([r["cold_rounds"] for r in rows]))
    summary = {"mean_warm_rounds": warm, "mean_cold_rounds": cold,
               "round_savings": cold / warm if warm else float("nan"),
               "target": target, "config": cfg}
    if markdown:
        print(f"\n### Warm-start tracking vs cold restart "
              f"(slow rotation {cfg['rate']} rad/tick, m={m} d={d} k={k} "
              f"K={cfg['K']}, target tan-theta {target:g})\n")
        print("| tick | warm rounds | warm tan | cold rounds | cold tan |")
        print("|------|-------------|----------|-------------|----------|")
        for r in rows:
            print(f"| {r['tick']} | {r['warm_rounds']:.0f} | "
                  f"{r['warm_tan']:.2e} | {r['cold_rounds']:.0f} | "
                  f"{r['cold_tan']:.2e} |")
        print(f"\nmean comm rounds/tick: warm **{warm:.1f}** vs cold "
              f"{cold:.1f} -> **{cold / warm:.2f}x fewer** rounds "
              "warm-started")
    return {"rows": rows, "summary": summary}


# ---------------------------------------------------- queue: batched serving

def _serve_all(svc: PCAService, reqs):
    ids = [svc.submit(ops, W0) for ops, W0 in reqs]
    svc.flush()
    return [svc.result(i) for i in ids]


def bench_queue(cfg, markdown: bool = True):
    m, d = cfg["m"], cfg["d"]
    topo = erdos_renyi(m, p=0.5, seed=0)
    reqs = ragged_requests(m, d, cfg["k"], cfg["requests"], n_base=cfg["n"])
    T, K = cfg["T_serve"], cfg["K"]
    svc = PCAService(topo, T=T, K=K, backend="stacked",
                     policy=AdmissionPolicy(max_batch=8, pad_n=16, pad_k=4))

    # warm-up pass compiles every (bucket, batch-size) program the mix needs
    resp = _serve_all(svc, reqs)
    if any(r is None for r in resp):     # must survive python -O
        raise RuntimeError("warm-up pass left requests unserved")
    warmup = dict(svc.stats)

    t0 = time.perf_counter()
    resp = _serve_all(svc, reqs)
    dt_queue = time.perf_counter() - t0
    cold_after = svc.stats["cold_launches"] - warmup["cold_launches"]
    warm_after = svc.stats["warm_launches"] - warmup["warm_launches"]

    # naive server baseline: one fresh driver per request (every request
    # pays its own trace+compile) — what the bucketed queue replaces
    naive_n = min(len(reqs), 6)
    t0 = time.perf_counter()
    for ops, W0 in reqs[:naive_n]:
        drv = IterationDriver(
            step=PowerStep.for_algorithm("deepca", K),
            engine=ConsensusEngine.for_algorithm("deepca", topo, K=K,
                                                 backend="stacked"))
        jax.block_until_ready(drv.run(ops, W0, T=T).carry[1])
    dt_naive = (time.perf_counter() - t0) * len(reqs) / naive_n

    out = {
        "requests": len(reqs), "T": T, "K": K,
        "batches_per_pass": warmup["batches"],
        "programs_compiled": warmup["cold_launches"],
        "cold_launches_after_warmup": cold_after,
        "warm_launches_after_warmup": warm_after,
        "queue_s": dt_queue, "queue_req_s": len(reqs) / dt_queue,
        "naive_est_s": dt_naive,
        "speedup_vs_naive": dt_naive / dt_queue,
        "padded_requests": warmup["padded_requests"],
    }
    if markdown:
        print(f"\n### Dynamic-batching queue ({len(reqs)} ragged requests, "
              f"m={m} d={d}, T={T}, K={K}; buckets pad n->16s, k->4s, "
              "batch->pow2<=8)\n")
        print(f"programs compiled for the whole mix: "
              f"{out['programs_compiled']} "
              f"(vs {len(reqs)} for per-request compilation)")
        print(f"after warm-up: cold launches = "
              f"{out['cold_launches_after_warmup']} "
              f"(recompilation-free), warm = "
              f"{out['warm_launches_after_warmup']}")
        print(f"queue: {dt_queue:.2f}s ({out['queue_req_s']:.1f} req/s) | "
              f"naive driver-per-request (est): {dt_naive:.2f}s -> "
              f"**{out['speedup_vs_naive']:.1f}x**")
    return out


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    cfg = dict(QUICK if quick else FULL)
    sections = {s for s in ("--drift", "--queue") if s in sys.argv} or \
        {"--drift", "--queue"}
    json_path = None
    if "--json" in sys.argv:
        # validate BEFORE the (long) benchmark runs, not after
        idx = sys.argv.index("--json") + 1
        if idx >= len(sys.argv) or sys.argv[idx].startswith("--"):
            raise SystemExit("--json needs an output path")
        json_path = sys.argv[idx]
    report = {"host_backend": jax.default_backend(), "quick": quick}
    if "--drift" in sections:
        report["drift"] = bench_drift(cfg)
    if "--queue" in sections:
        report["queue"] = bench_queue(cfg)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\n[json] wrote {json_path}")
