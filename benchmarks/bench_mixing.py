"""FastMix benchmarks: Prop. 1 validation + ConsensusEngine backend sweep.

Four entry points:

* :func:`main` (used by ``benchmarks.run``) — FastMix vs naive gossip
  contraction rates, measured vs theoretical, across topologies.
* :func:`sweep_backends` (``python benchmarks/bench_mixing.py --sweep``) —
  times the engine's three gossip backends (per-round ``stacked``, fused
  ``pallas`` kernel/polynomial, ``shard_map`` collectives) over a
  (topology, m, d, k, K) grid spanning ring / Erdős–Rényi / torus graphs up
  to m=64, and emits a comparison table with the fused-vs-stacked speedup
  per config.  Run with ``--sweep`` so fake host devices are set up before
  jax initialises and the shard_map rows can execute on CPU.
* :func:`sweep_batched` (``--batched``) — the multi-problem serving column:
  times ``IterationDriver.run_batch`` (one compiled vmap-over-problems
  launch) against B sequential driver runs of the same problems and
  reports problems/s plus the batched speedup.
* :func:`sweep_block_n` (``--block-n [128,256,...]``) — fused-kernel
  column-tile tuning: times the pallas gossip launch per ``block_n`` value
  (real kernel on TPU, interpret mode elsewhere) so the roadmap's "tune
  block_n on real TPU" item is a one-flag experiment; the winning value is
  deployed with the ``REPRO_FASTMIX_BLOCK_N`` env override (engines built
  with ``block_n=None`` read it).
* :func:`sweep_degraded` (``--degraded``) — the fleet-robustness table:
  sweeps dead-agent counts x per-round edge-dropout rates over
  ring/hypercube/er graphs, reporting the surviving spectral gap, the
  Prop. 1 contraction bound and the *measured* K-round consensus
  contraction under the corresponding :class:`TopologySchedule`.  Rows
  whose survivor graph disconnects are reported as such (gossip cannot
  contract there — the failure mode ``degrade_topology`` now refuses to
  hide).

``--json PATH`` writes every produced row to a JSON file (the CI workflow
uploads it as a build artifact); ``--quick`` shrinks grids/reps for CI.
"""
from __future__ import annotations

import csv
import json
import sys

if __name__ == "__main__" and ("--sweep" in sys.argv
                               or "--batched" in sys.argv):
    # must happen before the first jax backend initialisation; configure()
    # appends to XLA_FLAGS, and an explicit
    # --xla_force_host_platform_device_count already present in it wins
    from repro.runtime.config import configure
    configure(host_device_count=16)

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ConsensusEngine, complete, consensus_error,
                        erdos_renyi, fastmix, fastmix_eta, hypercube,
                        naive_mix, ring, torus2d)

TOPOLOGIES = [
    ("er50_p0.5", lambda: erdos_renyi(50, p=0.5, seed=0)),   # paper setting
    ("ring16", lambda: ring(16)),
    ("torus16x16", lambda: torus2d(16, 16)),                 # TPU pod fabric
    ("hypercube256", lambda: hypercube(256)),
]

# (topology, m, d, k, K) grid for the backend sweep; the ring (16, 1024,
# 8, 8) point is the acceptance config tracked in CHANGES.md / the PR
# table.  er/torus rows and the m=64 points cover the roadmap's "grow the
# grid" item (torus is the TPU-fabric-shaped graph; er is the paper's
# setting).  m=64 exceeds the 16 fake host devices, so those shard_map
# cells report as skipped off-pod.
SWEEP_CONFIGS = [
    ("ring", 8, 256, 8, 4),
    ("ring", 8, 1024, 8, 8),
    ("ring", 16, 256, 8, 4),
    ("ring", 16, 1024, 8, 4),
    ("ring", 16, 1024, 8, 8),
    ("ring", 16, 4096, 8, 8),
    ("er", 16, 1024, 8, 8),
    ("torus", 16, 1024, 8, 8),
    ("ring", 64, 1024, 8, 8),
    ("er", 64, 1024, 8, 8),
    ("torus", 64, 1024, 8, 8),
]

QUICK_SWEEP_CONFIGS = [
    ("ring", 8, 256, 8, 4),
    ("er", 16, 256, 8, 4),
    ("torus", 16, 256, 8, 4),
]


def _sweep_topology(kind: str, m: int):
    if kind == "ring":
        return ring(m)
    if kind == "er":
        return erdos_renyi(m, p=0.5, seed=0)
    if kind == "torus":
        side = int(round(m ** 0.5))
        if side * side != m:
            raise ValueError(f"torus sweep point needs square m, got {m}")
        return torus2d(side, side)
    raise ValueError(f"unknown sweep topology kind {kind!r}")


def main(writer=None) -> None:
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rng = np.random.default_rng(0)
    for name, make in TOPOLOGIES:
        topo = make()
        S = jnp.asarray(rng.standard_normal((topo.m, 64, 8)), jnp.float32)
        L = jnp.asarray(topo.mixing, jnp.float32)
        eta = fastmix_eta(topo.lambda2)
        e0 = float(consensus_error(S))
        for K in (5, 10, 20):
            t0 = time.perf_counter()
            out_f = fastmix(S, L, eta, K)
            out_f.block_until_ready()
            dt_f = time.perf_counter() - t0
            out_n = naive_mix(S, L, K)
            ef = float(consensus_error(out_f)) / e0
            en = float(consensus_error(out_n)) / e0
            writer.writerow([
                f"mixing/{name}/K{K}", f"{dt_f * 1e6:.1f}",
                f"fastmix={ef:.3e};naive={en:.3e};"
                f"bound={topo.fastmix_rate(K):.3e};"
                f"gap={topo.spectral_gap:.4f}"])


# ---------------------------------------------------------- backend sweep

def _median_us(fn, reps: int = 100) -> float:
    fn().block_until_ready()                  # compile + warm cache
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn().block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _backend_fns(topo, S, K):
    """Per-backend jitted mix closures for one config (None = unavailable)."""
    m = topo.m
    fns = {}
    eng_s = ConsensusEngine(topo, K=K, backend="stacked")
    fns["stacked"] = ("per-round einsum", lambda: eng_s.mix(S))

    eng_p = ConsensusEngine(topo, K=K, backend="pallas")
    flavour = ("pallas kernel" if jax.default_backend() == "tpu"
               else "poly fallback")
    fns["pallas-fused"] = (flavour, lambda: eng_p.mix(S))

    if len(jax.devices()) >= m:
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.devices()[:m]), ("agents",))
        eng_d = ConsensusEngine(topo, K=K, backend="shard_map", mesh=mesh)
        fns["shard_map"] = ("collective_permute", lambda: eng_d.mix(S))
    else:
        fns["shard_map"] = (f"skipped ({len(jax.devices())} devices < {m})",
                            None)
    return fns


def sweep_backends(writer=None, configs=SWEEP_CONFIGS, reps: int = 100,
                   markdown: bool = False):
    """Time every gossip backend over the (topology, m, d, k, K) grid."""
    own = writer is None
    if own and not markdown:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rows = []
    rng = np.random.default_rng(0)
    for (kind, m, d, k, K) in configs:
        topo = _sweep_topology(kind, m)
        S = jnp.asarray(rng.standard_normal((m, d, k)), jnp.float32)
        fns = _backend_fns(topo, S, K)
        timings = {}
        for backend, (flavour, fn) in fns.items():
            us = _median_us(fn, reps) if fn is not None else float("nan")
            timings[backend] = (flavour, us)
            if writer is not None:
                writer.writerow([
                    f"mixing_backend/{topo.name}/d{d}k{k}K{K}/{backend}",
                    f"{us:.1f}", flavour])
        speedup = timings["stacked"][1] / timings["pallas-fused"][1]
        rows.append(((topo.name, m, d, k, K), timings, speedup))
    if markdown:
        _print_markdown(rows)
    return rows


def _print_markdown(rows) -> None:
    host = jax.default_backend()
    print(f"\n### FastMix backend sweep (host backend: {host}, "
          f"{len(jax.devices())} devices)\n")
    print("| topology | m | d | k | K | stacked (per-round) | pallas-fused | "
          "shard_map | fused speedup |")
    print("|----------|---|---|---|---|---------------------|--------------|"
          "-----------|---------------|")
    for (name, m, d, k, K), t, speedup in rows:
        def cell(b):
            flavour, us = t[b]
            if us != us:                      # NaN -> unavailable
                return flavour
            return f"{us:.0f} µs ({flavour})"
        print(f"| {name} | {m} | {d} | {k} | {K} | {cell('stacked')} | "
              f"{cell('pallas-fused')} | {cell('shard_map')} | "
              f"**{speedup:.2f}×** |")


# ---------------------------------------------------------- block_n sweep

#: Tile widths for the fused-kernel block_n sweep (the roadmap's "tune
#: block_n on real TPU" knob; REPRO_FASTMIX_BLOCK_N is the env override).
BLOCK_N_VALUES = (128, 256, 512, 1024)

BLOCK_N_CONFIGS = [
    ("ring", 16, 1024, 8, 8),           # the acceptance config
    ("er", 16, 4096, 8, 8),             # wider column axis: more tiles
]

QUICK_BLOCK_N_CONFIGS = [
    ("ring", 8, 256, 8, 4),
]


def sweep_block_n(values=BLOCK_N_VALUES, configs=BLOCK_N_CONFIGS,
                  reps: int = 20, markdown: bool = False,
                  record: bool = False):
    """Time the fused gossip launch across column-tile widths.

    On TPU this times the real Pallas kernel (the tuning experiment the
    roadmap asks for); elsewhere the kernel runs in interpret mode — far
    slower in absolute terms, but it exercises the block_n plumbing
    end-to-end so the one-flag experiment is already wired when a TPU host
    picks it up.

    ``record=True`` (CLI ``--record``) writes each config's winning width
    into the persistent autotune cache (kernel ``fastmix``, keyed on the
    kernel-facing ``(m, d*k)`` bucket), which every engine built with
    ``block_n=None`` then picks up automatically — the measure→deploy loop
    with no env var needed (``REPRO_FASTMIX_BLOCK_N`` still wins when set).
    """
    from repro.kernels import autotune
    from repro.kernels.fastmix import DEFAULT_BLOCK_N
    on_tpu = jax.default_backend() == "tpu"
    flavour = "pallas kernel" if on_tpu else "interpret mode"
    interpret = None if on_tpu else True
    rows = []
    rng = np.random.default_rng(0)
    for (kind, m, d, k, K) in configs:
        topo = _sweep_topology(kind, m)
        S = jnp.asarray(rng.standard_normal((m, d, k)), jnp.float32)
        per = []
        for bn in values:
            eng = ConsensusEngine(topo, K=K, backend="pallas",
                                  interpret=interpret, block_n=int(bn))
            per.append((int(bn), _median_us(lambda: eng.mix(S), reps)))
        base = dict(per).get(DEFAULT_BLOCK_N, per[0][1])
        rows.append(((topo.name, m, d, k, K), per, base))
        if record:
            best_bn, best_us = min(per, key=lambda p: p[1])
            key = autotune.record("fastmix", (m, d * k), S.dtype,
                                  {"block_n": best_bn,
                                   "us": round(best_us, 1)})
            print(f"[autotune] recorded {key}: block_n={best_bn}",
                  file=sys.stderr)
    if markdown:
        print(f"\n### Fused FastMix block_n sweep ({flavour}; "
              f"default block_n={DEFAULT_BLOCK_N}, "
              f"override with REPRO_FASTMIX_BLOCK_N)\n")
        header = "| topology | m | d | k | K | " + " | ".join(
            f"bn={bn}" for bn, _ in rows[0][1]) + " | best |"
        print(header)
        print("|" + "---|" * (5 + len(rows[0][1]) + 1))
        for (name, m, d, k, K), per, base in rows:
            best_bn = min(per, key=lambda p: p[1])[0]
            cells = " | ".join(f"{us:.0f} µs ({base / us:.2f}×)"
                               for _, us in per)
            print(f"| {name} | {m} | {d} | {k} | {K} | {cells} | "
                  f"**bn={best_bn}** |")
    return rows, flavour


# ---------------------------------------------------------- batched sweep

# (B, m, d, k, T, K) grid for run_batch vs sequential driver runs; the
# (8, ...) row is the acceptance config ("run_batch(B=8) beats 8 sequential
# driver runs on the CPU bench host").
BATCHED_CONFIGS = [
    (4, 8, 256, 4, 20, 5),
    (8, 16, 256, 4, 20, 6),
    (8, 16, 1024, 8, 20, 6),
    (16, 16, 256, 4, 20, 6),
]

QUICK_BATCHED_CONFIGS = [
    (4, 8, 64, 3, 10, 4),
    (8, 8, 64, 3, 10, 4),
]


def sweep_batched(writer=None, configs=BATCHED_CONFIGS, reps: int = 10,
                  markdown: bool = False):
    """run_batch (one vmapped launch) vs B sequential driver runs."""
    import time

    from repro.core import (ConsensusEngine, IterationDriver, PowerStep,
                            synthetic_problem_batch)

    own = writer is None
    if own and not markdown:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rows = []
    for (B, m, d, k, T, K) in configs:
        topo = erdos_renyi(m, p=0.5, seed=0)
        problems, W0 = synthetic_problem_batch(B, m, d, k, n_per_agent=32,
                                               seed=0)
        driver = IterationDriver(
            step=PowerStep.for_algorithm("deepca", K),
            engine=ConsensusEngine.for_algorithm(
                "deepca", topo, K=K, backend="stacked"))

        jax.block_until_ready(driver.run_batch(problems, W0, T=T).W)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(driver.run_batch(problems, W0, T=T).W)
        batch_us = (time.perf_counter() - t0) / reps * 1e6

        # baseline 1 — warm driver: repeated run() calls on ONE driver hit
        # its jitted-program cache (per-(T, kind); added with run_batch)
        for p, w in zip(problems, W0):          # warm per-problem paths
            jax.block_until_ready(driver.run(p, w, T=T).carry[1])
        t0 = time.perf_counter()
        for _ in range(reps):
            for p, w in zip(problems, W0):
                jax.block_until_ready(driver.run(p, w, T=T).carry[1])
        warm_us = (time.perf_counter() - t0) / reps * 1e6

        # baseline 2 — fresh driver per request (B independent driver
        # runs, the deepca()-per-call serving pattern): every run
        # re-traces its scan, so this measures what run_batch's single
        # launch actually replaces in a naive server
        fresh_reps = min(reps, 3)
        t0 = time.perf_counter()
        for _ in range(fresh_reps):
            for p, w in zip(problems, W0):
                d2 = IterationDriver(
                    step=PowerStep.for_algorithm("deepca", K),
                    engine=ConsensusEngine.for_algorithm(
                        "deepca", topo, K=K, backend="stacked"))
                jax.block_until_ready(d2.run(p, w, T=T).carry[1])
        fresh_us = (time.perf_counter() - t0) / fresh_reps * 1e6

        speedup_warm = warm_us / batch_us
        speedup_fresh = fresh_us / batch_us
        pps = B / (batch_us / 1e6)
        if writer is not None:
            writer.writerow([
                f"mixing_batched/{topo.name}/B{B}d{d}k{k}T{T}K{K}",
                f"{batch_us:.1f}",
                f"seq_warm={warm_us:.1f};seq_fresh={fresh_us:.1f};"
                f"speedup_vs_warm={speedup_warm:.2f};"
                f"speedup_vs_fresh={speedup_fresh:.2f};"
                f"problems_per_s={pps:.1f}"])
        rows.append(((B, m, d, k, T, K), batch_us, warm_us, fresh_us,
                     speedup_warm, speedup_fresh, pps))
    if markdown:
        _print_batched_markdown(rows)
    return rows


def _print_batched_markdown(rows) -> None:
    print(f"\n### Batched multi-problem serving (host backend: "
          f"{jax.default_backend()}; run_batch = one vmapped launch; "
          "'warm' = one driver's jit cache reused, 'fresh' = driver per "
          "request)\n")
    print("| B | m | d | k | T | K | run_batch | B seq (warm) | "
          "B seq (fresh) | vs warm | vs fresh | problems/s |")
    print("|---|---|---|---|---|---|-----------|--------------|"
          "---------------|---------|----------|------------|")
    for (B, m, d, k, T, K), bus, wus, fus, sw, sf, pps in rows:
        print(f"| {B} | {m} | {d} | {k} | {T} | {K} | {bus / 1e3:.1f} ms | "
              f"{wus / 1e3:.1f} ms | {fus / 1e3:.1f} ms | {sw:.2f}× | "
              f"**{sf:.2f}×** | {pps:.0f} |")


# ---------------------------------------------------------- degraded sweep

DEAD_COUNTS = (0, 1, 2, 4)
DROP_RATES = (0.0, 0.1, 0.3)


def sweep_degraded(writer=None, m: int = 16, K: int = 8, steps: int = 6,
                   dead_counts=DEAD_COUNTS, drops=DROP_RATES,
                   markdown: bool = False, seed: int = 0):
    """Dead-agents x edge-dropout robustness sweep over ring/hypercube/er."""
    from repro.core import TopologySchedule, DynamicConsensusEngine
    from repro.runtime import DisconnectedTopologyError, degrade_topology

    own = writer is None
    if own and not markdown:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rng = np.random.default_rng(seed)
    topologies = [ring(m), hypercube(m), erdos_renyi(m, p=0.5, seed=seed)]
    rows = []
    for topo in topologies:
        for nd in dead_counts:
            dead = sorted(rng.choice(m, size=nd, replace=False).tolist())
            try:
                base = degrade_topology(topo, dead) if nd else topo
            except DisconnectedTopologyError:
                for p in drops:
                    rows.append((topo.name, nd, p, None))
                    if writer is not None:
                        writer.writerow([
                            f"mixing_degraded/{topo.name}/dead{nd}/drop{p}",
                            "nan", "disconnected"])
                continue
            for p in drops:
                sched = TopologySchedule.edge_dropout(base, p, seed=seed + 1)
                eng = DynamicConsensusEngine(schedule=sched, K=K,
                                             backend="stacked")
                S = jnp.asarray(
                    rng.standard_normal((base.m, 64, 8)), jnp.float32)
                e0 = float(consensus_error(S))
                gaps, contractions, bounds = [], [], []
                for t in range(steps):
                    tp = sched.topology_at(t)
                    gaps.append(tp.spectral_gap)
                    bounds.append(tp.fastmix_rate(K))
                    contractions.append(
                        float(consensus_error(eng.mix_at(S, t))) / e0)
                row = (topo.name, nd, p,
                       (float(np.min(gaps)), float(np.mean(contractions)),
                        float(np.mean(bounds)), base.m))
                rows.append(row)
                if writer is not None:
                    gap, meas, bound, surv = row[3]
                    writer.writerow([
                        f"mixing_degraded/{topo.name}/dead{nd}/drop{p}",
                        f"{meas:.3e}",
                        f"survivors={surv};min_gap={gap:.4f};"
                        f"bound={bound:.3e};K={K}"])
    if markdown:
        _print_degraded_markdown(rows, m, K, steps)
    return rows


def _print_degraded_markdown(rows, m: int, K: int, steps: int) -> None:
    print(f"\n### Fault-degraded FastMix sweep (m={m}, K={K}, "
          f"{steps} schedule steps, measured = mean K-round consensus "
          f"contraction)\n")
    print("| topology | dead agents | edge dropout | survivors | min gap | "
          "measured contraction | Prop. 1 bound |")
    print("|----------|-------------|--------------|-----------|---------|"
          "----------------------|---------------|")
    for name, nd, p, stats in rows:
        if stats is None:
            print(f"| {name} | {nd} | {p} | — | — | DISCONNECTED "
                  "(gossip cannot contract) | — |")
            continue
        gap, meas, bound, surv = stats
        print(f"| {name} | {nd} | {p} | {surv} | {gap:.4f} | {meas:.3e} | "
              f"{bound:.3e} |")


def _arg_value(flag: str, default=None):
    if flag in sys.argv:
        idx = sys.argv.index(flag) + 1
        if idx < len(sys.argv):         # bare trailing flag -> default
            return sys.argv[idx]
    return default


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    reps = int(_arg_value("--reps", 5 if quick else 0) or 0)
    report = {"host_backend": jax.default_backend(),
              "devices": len(jax.devices())}
    ran_any = False
    if "--sweep" in sys.argv:
        rows = sweep_backends(
            writer=None, markdown=True,
            configs=QUICK_SWEEP_CONFIGS if quick else SWEEP_CONFIGS,
            reps=reps or 100)
        report["sweep"] = [
            {"topology": name, "m": m, "d": d, "k": k, "K": K,
             # skipped cells carry us=NaN, which is not valid JSON -> null
             "timings_us": {b: {"flavour": fl,
                                "us": us if us == us else None}
                            for b, (fl, us) in t.items()},
             "fused_speedup": sp}
            for (name, m, d, k, K), t, sp in rows]
        ran_any = True
    if "--batched" in sys.argv:
        rows = sweep_batched(
            writer=None, markdown=True,
            configs=QUICK_BATCHED_CONFIGS if quick else BATCHED_CONFIGS,
            reps=reps or 10)
        report["batched"] = [
            {"B": B, "m": m, "d": d, "k": k, "T": T, "K": K,
             "run_batch_us": bus, "sequential_warm_us": wus,
             "sequential_fresh_us": fus, "speedup_vs_warm": sw,
             "speedup_vs_fresh": sf, "problems_per_s": pps}
            for (B, m, d, k, T, K), bus, wus, fus, sw, sf, pps in rows]
        ran_any = True
    if "--block-n" in sys.argv:
        vals = _arg_value("--block-n")
        # bare `--block-n` (or `--block-n` followed by another flag) runs
        # the default width grid; otherwise a comma list: --block-n 128,256
        if vals is None or vals.startswith("--"):
            values = BLOCK_N_VALUES
        else:
            values = tuple(int(v) for v in vals.split(","))
        rows, flavour = sweep_block_n(
            values=values, markdown=True,
            configs=QUICK_BLOCK_N_CONFIGS if quick else BLOCK_N_CONFIGS,
            reps=reps or 20, record="--record" in sys.argv)
        report["block_n"] = {
            "flavour": flavour,
            "rows": [{"topology": name, "m": m, "d": d, "k": k, "K": K,
                      "timings_us": {str(bn): us for bn, us in per}}
                     for (name, m, d, k, K), per, _ in rows]}
        ran_any = True
    if "--degraded" in sys.argv:
        rows = sweep_degraded(writer=None, markdown=True)
        report["degraded"] = [
            {"topology": name, "dead": nd, "drop": p,
             "stats": None if stats is None else
             {"min_gap": stats[0], "measured_contraction": stats[1],
              "prop1_bound": stats[2], "survivors": stats[3]}}
            for name, nd, p, stats in rows]
        ran_any = True
    if not ran_any:
        main()
    json_path = _arg_value("--json")
    if json_path and ran_any:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\n[json] wrote {json_path}")
