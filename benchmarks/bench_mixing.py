"""Proposition 1 validation: FastMix vs naive gossip contraction rates,
measured vs theoretical, across topologies (incl. the TPU-native torus)."""
from __future__ import annotations

import csv
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (complete, consensus_error, erdos_renyi, fastmix,
                        fastmix_eta, hypercube, naive_mix, ring, torus2d)

TOPOLOGIES = [
    ("er50_p0.5", lambda: erdos_renyi(50, p=0.5, seed=0)),   # paper setting
    ("ring16", lambda: ring(16)),
    ("torus16x16", lambda: torus2d(16, 16)),                 # TPU pod fabric
    ("hypercube256", lambda: hypercube(256)),
]


def main(writer=None) -> None:
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rng = np.random.default_rng(0)
    for name, make in TOPOLOGIES:
        topo = make()
        S = jnp.asarray(rng.standard_normal((topo.m, 64, 8)), jnp.float32)
        L = jnp.asarray(topo.mixing, jnp.float32)
        eta = fastmix_eta(topo.lambda2)
        e0 = float(consensus_error(S))
        for K in (5, 10, 20):
            t0 = time.perf_counter()
            out_f = fastmix(S, L, eta, K)
            out_f.block_until_ready()
            dt_f = time.perf_counter() - t0
            out_n = naive_mix(S, L, K)
            ef = float(consensus_error(out_f)) / e0
            en = float(consensus_error(out_n)) / e0
            writer.writerow([
                f"mixing/{name}/K{K}", f"{dt_f * 1e6:.1f}",
                f"fastmix={ef:.3e};naive={en:.3e};"
                f"bound={topo.fastmix_rate(K):.3e};"
                f"gap={topo.spectral_gap:.4f}"])


if __name__ == "__main__":
    main()
