"""FastMix benchmarks: Prop. 1 validation + ConsensusEngine backend sweep.

Three entry points:

* :func:`main` (used by ``benchmarks.run``) — FastMix vs naive gossip
  contraction rates, measured vs theoretical, across topologies.
* :func:`sweep_backends` (``python benchmarks/bench_mixing.py --sweep``) —
  times the engine's three gossip backends (per-round ``stacked``, fused
  ``pallas`` kernel/polynomial, ``shard_map`` collectives) over an
  (m, d, k, K) grid and emits a comparison table with the fused-vs-stacked
  speedup per config.  Run with ``--sweep`` so fake host devices are set up
  before jax initialises and the shard_map rows can execute on CPU.
* :func:`sweep_degraded` (``--degraded``) — the fleet-robustness table:
  sweeps dead-agent counts x per-round edge-dropout rates over
  ring/hypercube/er graphs, reporting the surviving spectral gap, the
  Prop. 1 contraction bound and the *measured* K-round consensus
  contraction under the corresponding :class:`TopologySchedule`.  Rows
  whose survivor graph disconnects are reported as such (gossip cannot
  contract there — the failure mode ``degrade_topology`` now refuses to
  hide).
"""
from __future__ import annotations

import csv
import os
import sys

if __name__ == "__main__" and "--sweep" in sys.argv:
    # must happen before the first jax backend initialisation; append so a
    # pre-existing XLA_FLAGS doesn't silently drop the fake devices (an
    # explicit --xla_force_host_platform_device_count in it still wins)
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=16").strip()

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ConsensusEngine, complete, consensus_error,
                        erdos_renyi, fastmix, fastmix_eta, hypercube,
                        naive_mix, ring, torus2d)

TOPOLOGIES = [
    ("er50_p0.5", lambda: erdos_renyi(50, p=0.5, seed=0)),   # paper setting
    ("ring16", lambda: ring(16)),
    ("torus16x16", lambda: torus2d(16, 16)),                 # TPU pod fabric
    ("hypercube256", lambda: hypercube(256)),
]

# (m, d, k, K) grid for the backend sweep; the (16, 1024, 8, 8) point is the
# acceptance config tracked in CHANGES.md / the PR table.
SWEEP_CONFIGS = [
    (8, 256, 8, 4),
    (8, 1024, 8, 8),
    (16, 256, 8, 4),
    (16, 1024, 8, 4),
    (16, 1024, 8, 8),
    (16, 4096, 8, 8),
]


def main(writer=None) -> None:
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rng = np.random.default_rng(0)
    for name, make in TOPOLOGIES:
        topo = make()
        S = jnp.asarray(rng.standard_normal((topo.m, 64, 8)), jnp.float32)
        L = jnp.asarray(topo.mixing, jnp.float32)
        eta = fastmix_eta(topo.lambda2)
        e0 = float(consensus_error(S))
        for K in (5, 10, 20):
            t0 = time.perf_counter()
            out_f = fastmix(S, L, eta, K)
            out_f.block_until_ready()
            dt_f = time.perf_counter() - t0
            out_n = naive_mix(S, L, K)
            ef = float(consensus_error(out_f)) / e0
            en = float(consensus_error(out_n)) / e0
            writer.writerow([
                f"mixing/{name}/K{K}", f"{dt_f * 1e6:.1f}",
                f"fastmix={ef:.3e};naive={en:.3e};"
                f"bound={topo.fastmix_rate(K):.3e};"
                f"gap={topo.spectral_gap:.4f}"])


# ---------------------------------------------------------- backend sweep

def _median_us(fn, reps: int = 100) -> float:
    fn().block_until_ready()                  # compile + warm cache
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn().block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _backend_fns(topo, S, K):
    """Per-backend jitted mix closures for one config (None = unavailable)."""
    m = topo.m
    fns = {}
    eng_s = ConsensusEngine(topo, K=K, backend="stacked")
    fns["stacked"] = ("per-round einsum", lambda: eng_s.mix(S))

    eng_p = ConsensusEngine(topo, K=K, backend="pallas")
    flavour = ("pallas kernel" if jax.default_backend() == "tpu"
               else "poly fallback")
    fns["pallas-fused"] = (flavour, lambda: eng_p.mix(S))

    if len(jax.devices()) >= m:
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.devices()[:m]), ("agents",))
        eng_d = ConsensusEngine(topo, K=K, backend="shard_map", mesh=mesh)
        fns["shard_map"] = ("collective_permute", lambda: eng_d.mix(S))
    else:
        fns["shard_map"] = (f"skipped ({len(jax.devices())} devices < {m})",
                            None)
    return fns


def sweep_backends(writer=None, configs=SWEEP_CONFIGS, reps: int = 100,
                   markdown: bool = False):
    """Time every gossip backend over the (m, d, k, K) grid."""
    own = writer is None
    if own and not markdown:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rows = []
    rng = np.random.default_rng(0)
    for (m, d, k, K) in configs:
        topo = ring(m)
        S = jnp.asarray(rng.standard_normal((m, d, k)), jnp.float32)
        fns = _backend_fns(topo, S, K)
        timings = {}
        for backend, (flavour, fn) in fns.items():
            us = _median_us(fn, reps) if fn is not None else float("nan")
            timings[backend] = (flavour, us)
            if writer is not None:
                writer.writerow([
                    f"mixing_backend/{topo.name}/d{d}k{k}K{K}/{backend}",
                    f"{us:.1f}", flavour])
        speedup = timings["stacked"][1] / timings["pallas-fused"][1]
        rows.append(((m, d, k, K), timings, speedup))
    if markdown:
        _print_markdown(rows)
    return rows


def _print_markdown(rows) -> None:
    host = jax.default_backend()
    print(f"\n### FastMix backend sweep (host backend: {host}, "
          f"{len(jax.devices())} devices, ring topology)\n")
    print("| m | d | k | K | stacked (per-round) | pallas-fused | "
          "shard_map | fused speedup |")
    print("|---|---|---|---|---------------------|--------------|"
          "-----------|---------------|")
    for (m, d, k, K), t, speedup in rows:
        def cell(b):
            flavour, us = t[b]
            if us != us:                      # NaN -> unavailable
                return flavour
            return f"{us:.0f} µs ({flavour})"
        print(f"| {m} | {d} | {k} | {K} | {cell('stacked')} | "
              f"{cell('pallas-fused')} | {cell('shard_map')} | "
              f"**{speedup:.2f}×** |")


# ---------------------------------------------------------- degraded sweep

DEAD_COUNTS = (0, 1, 2, 4)
DROP_RATES = (0.0, 0.1, 0.3)


def sweep_degraded(writer=None, m: int = 16, K: int = 8, steps: int = 6,
                   dead_counts=DEAD_COUNTS, drops=DROP_RATES,
                   markdown: bool = False, seed: int = 0):
    """Dead-agents x edge-dropout robustness sweep over ring/hypercube/er."""
    from repro.core import TopologySchedule, DynamicConsensusEngine
    from repro.runtime import DisconnectedTopologyError, degrade_topology

    own = writer is None
    if own and not markdown:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rng = np.random.default_rng(seed)
    topologies = [ring(m), hypercube(m), erdos_renyi(m, p=0.5, seed=seed)]
    rows = []
    for topo in topologies:
        for nd in dead_counts:
            dead = sorted(rng.choice(m, size=nd, replace=False).tolist())
            try:
                base = degrade_topology(topo, dead) if nd else topo
            except DisconnectedTopologyError:
                for p in drops:
                    rows.append((topo.name, nd, p, None))
                    if writer is not None:
                        writer.writerow([
                            f"mixing_degraded/{topo.name}/dead{nd}/drop{p}",
                            "nan", "disconnected"])
                continue
            for p in drops:
                sched = TopologySchedule.edge_dropout(base, p, seed=seed + 1)
                eng = DynamicConsensusEngine(schedule=sched, K=K,
                                             backend="stacked")
                S = jnp.asarray(
                    rng.standard_normal((base.m, 64, 8)), jnp.float32)
                e0 = float(consensus_error(S))
                gaps, contractions, bounds = [], [], []
                for t in range(steps):
                    tp = sched.topology_at(t)
                    gaps.append(tp.spectral_gap)
                    bounds.append(tp.fastmix_rate(K))
                    contractions.append(
                        float(consensus_error(eng.mix_at(S, t))) / e0)
                row = (topo.name, nd, p,
                       (float(np.min(gaps)), float(np.mean(contractions)),
                        float(np.mean(bounds)), base.m))
                rows.append(row)
                if writer is not None:
                    gap, meas, bound, surv = row[3]
                    writer.writerow([
                        f"mixing_degraded/{topo.name}/dead{nd}/drop{p}",
                        f"{meas:.3e}",
                        f"survivors={surv};min_gap={gap:.4f};"
                        f"bound={bound:.3e};K={K}"])
    if markdown:
        _print_degraded_markdown(rows, m, K, steps)
    return rows


def _print_degraded_markdown(rows, m: int, K: int, steps: int) -> None:
    print(f"\n### Fault-degraded FastMix sweep (m={m}, K={K}, "
          f"{steps} schedule steps, measured = mean K-round consensus "
          f"contraction)\n")
    print("| topology | dead agents | edge dropout | survivors | min gap | "
          "measured contraction | Prop. 1 bound |")
    print("|----------|-------------|--------------|-----------|---------|"
          "----------------------|---------------|")
    for name, nd, p, stats in rows:
        if stats is None:
            print(f"| {name} | {nd} | {p} | — | — | DISCONNECTED "
                  "(gossip cannot contract) | — |")
            continue
        gap, meas, bound, surv = stats
        print(f"| {name} | {nd} | {p} | {surv} | {gap:.4f} | {meas:.3e} | "
              f"{bound:.3e} |")


if __name__ == "__main__":
    if "--sweep" in sys.argv:
        sweep_backends(writer=None, markdown=True)
    elif "--degraded" in sys.argv:
        sweep_degraded(writer=None, markdown=True)
    else:
        main()
