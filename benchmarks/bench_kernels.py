"""Pallas kernel benches (interpret mode on CPU = correctness-scale timings;
the BlockSpec tiling is the TPU deliverable).  Reports kernel vs jnp-oracle
wall time and the analytic v5e roofline time for each shape."""
from __future__ import annotations

import csv
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def main(writer=None) -> None:
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])

    rng = np.random.default_rng(0)
    # gram: paper Eqn. 5.1 covariance formation
    for n, d in ((512, 256), (1024, 512)):
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        t_ref = _time(lambda a: ref.gram_ref(a), x)
        t_k = _time(lambda a: ops.gram(a, interpret=True), x)
        flops = 2 * n * d * d
        v5e = max(flops / PEAK_FLOPS, (n * d + d * d) * 4 / HBM_BW)
        writer.writerow([f"kernel/gram/{n}x{d}", f"{t_k * 1e6:.1f}",
                         f"ref_us={t_ref * 1e6:.1f};"
                         f"v5e_roofline_us={v5e * 1e6:.2f}"])
    # power_matmul: Alg. 1 local power step
    for d, k in ((512, 8), (1024, 32)):
        a = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
        t_ref = _time(lambda *z: ref.power_matmul_ref(*z), a, w)
        t_k = _time(lambda *z: ops.power_matmul(*z, interpret=True), a, w)
        flops = 2 * d * d * k
        v5e = max(flops / PEAK_FLOPS, (d * d + 2 * d * k) * 4 / HBM_BW)
        writer.writerow([f"kernel/power_matmul/{d}x{k}", f"{t_k * 1e6:.1f}",
                         f"ref_us={t_ref * 1e6:.1f};"
                         f"v5e_roofline_us={v5e * 1e6:.2f}"])
    # flash attention
    for s, hd in ((256, 64),):
        q = jnp.asarray(rng.standard_normal((1, 4, s, hd)), jnp.float32)
        kv = jnp.asarray(rng.standard_normal((1, 4, s, hd)), jnp.float32)
        t_ref = _time(lambda *z: ref.mha_ref(*z), q, kv, kv)
        t_k = _time(lambda *z: ops.flash_attention(
            *z, block_q=64, block_kv=64, interpret=True), q, kv, kv)
        flops = 4 * 4 * s * s * hd
        v5e = flops / PEAK_FLOPS
        writer.writerow([f"kernel/flash/{s}x{hd}", f"{t_k * 1e6:.1f}",
                         f"ref_us={t_ref * 1e6:.1f};"
                         f"v5e_roofline_us={v5e * 1e6:.2f}"])


if __name__ == "__main__":
    main()
