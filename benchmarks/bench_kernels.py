"""Pallas kernel benches (interpret mode on CPU = correctness-scale timings;
the BlockSpec tiling is the TPU deliverable) + the PR-5 per-iteration
step-path breakdown.

Sections
--------
* kernel-vs-oracle rows (gram / power_matmul / flash): kernel wall time vs
  the jnp oracle and the analytic v5e roofline time, as before.
* ``orth`` rows: batched CholeskyQR2 (``kernels/cholqr.py``) vs the seed
  ``jnp.linalg.qr`` Householder path across (m, d, k) shapes, with
  orthonormality and subspace-parity columns.
* ``step`` rows: the full DeEPCA per-iteration compute path — local apply,
  mix+track, orthonormalization — timed stage by stage and end to end for
  the *seed* path (unfused apply -> fused-poly ``mix_track`` -> Householder
  QR, i.e. the PR-4 state) vs the *fast* path
  (``engine.apply_mix_track`` -> CholeskyQR2).  The ``parity`` column is
  the sign-adjusted max-abs difference between the two paths' iterates.
* ``fused`` rows: bit-equality of the engine's ``apply_mix_track`` poly
  fallback vs the explicit ``local_apply`` + ``mix_track`` composition,
  and interpret-mode kernel parity for ``apply_track_fused``.

Every parity/orthonormality row carries its tolerance and an ``ok`` flag;
:func:`main` raises ``RuntimeError`` after reporting if any row failed, so
the CI quick-bench job gates on numerical health, not just on running.

CLI
---
``--json PATH`` exports the rows (+ host metadata); ``--quick`` shrinks the
shape grid for CI; ``--record`` writes the measured per-shape
orthonormalization winner into the persistent autotune cache
(``{"householder": 0|1}`` under kernel ``cholqr`` — consulted by
``core/step.qr_orth``), closing the measure→deploy loop.
"""
from __future__ import annotations

import csv
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from repro.kernels.cholqr import cholqr2
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

#: (m, d, k, K) step-path shapes; the k sweep shows the CholeskyQR2
#: crossover (Householder's panel cost grows with k^2 and never
#: vectorises; the ISSUE's "dominates step time at large k" regime).
STEP_SHAPES = [(16, 512, 8, 8), (16, 1024, 16, 8), (16, 1024, 32, 8)]
QUICK_STEP_SHAPES = [(8, 256, 8, 4), (8, 256, 16, 4)]

ORTH_SHAPES = [(16, 512, 8), (16, 1024, 8), (16, 1024, 16), (16, 1024, 32),
               (50, 300, 5)]
QUICK_ORTH_SHAPES = [(8, 256, 8), (8, 256, 16)]

#: Step-path parity tolerance (fp32, sign-adjusted iterates; both paths
#: run identical HIGHEST-precision matmul math up to summation order).
PARITY_TOL = 5e-5
#: Orthonormality tolerance for CholeskyQR2 output (fp32).
ORTH_TOL = 5e-6


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def _row(writer, rows, name, us, **extras):
    rows.append({"name": name, "us": round(float(us), 2), **extras})
    derived = ";".join(f"{k}={v:.3e}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in extras.items())
    writer.writerow([name, f"{us:.1f}", derived])


def _orth_err(Q):
    k = Q.shape[-1]
    return float(jnp.max(jnp.abs(
        jnp.einsum("...dk,...dl->...kl", Q, Q) - jnp.eye(k, dtype=Q.dtype))))


def _subspace_err(Q, Qref):
    P = jnp.einsum("...dk,...ek->...de", Q, Q)
    Pr = jnp.einsum("...dk,...ek->...de", Qref, Qref)
    return float(jnp.max(jnp.abs(P - Pr)))


# ------------------------------------------------------------ bench pieces
def kernel_rows(writer, rows, quick: bool) -> None:
    """The original kernel-vs-oracle section (gram/power_matmul/flash)."""
    rng = np.random.default_rng(0)
    gram_shapes = ((512, 256),) if quick else ((512, 256), (1024, 512))
    for n, d in gram_shapes:
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        t_ref = _time(lambda a: ref.gram_ref(a), x)
        t_k = _time(lambda a: ops.gram(a, interpret=True), x)
        flops = 2 * n * d * d
        v5e = max(flops / PEAK_FLOPS, (n * d + d * d) * 4 / HBM_BW)
        _row(writer, rows, f"kernel/gram/{n}x{d}", t_k * 1e6,
             ref_us=round(t_ref * 1e6, 1), v5e_roofline_us=v5e * 1e6)
    pm_shapes = ((512, 8),) if quick else ((512, 8), (1024, 32))
    for d, k in pm_shapes:
        a = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
        t_ref = _time(lambda *z: ref.power_matmul_ref(*z), a, w)
        t_k = _time(lambda *z: ops.power_matmul(*z, interpret=True), a, w)
        flops = 2 * d * d * k
        v5e = max(flops / PEAK_FLOPS, (d * d + 2 * d * k) * 4 / HBM_BW)
        _row(writer, rows, f"kernel/power_matmul/{d}x{k}", t_k * 1e6,
             ref_us=round(t_ref * 1e6, 1), v5e_roofline_us=v5e * 1e6)
    if not quick:
        for s, hd in ((256, 64),):
            q = jnp.asarray(rng.standard_normal((1, 4, s, hd)), jnp.float32)
            kv = jnp.asarray(rng.standard_normal((1, 4, s, hd)), jnp.float32)
            t_ref = _time(lambda *z: ref.mha_ref(*z), q, kv, kv)
            t_k = _time(lambda *z: ops.flash_attention(
                *z, block_q=64, block_kv=64, interpret=True), q, kv, kv)
            flops = 4 * 4 * s * s * hd
            _row(writer, rows, f"kernel/flash/{s}x{hd}", t_k * 1e6,
                 ref_us=round(t_ref * 1e6, 1),
                 v5e_roofline_us=flops / PEAK_FLOPS * 1e6)


def orth_rows(writer, rows, quick: bool, record: bool) -> None:
    """CholeskyQR2 vs Householder across shapes (the Eqn. 3.3 hot spot)."""
    rng = np.random.default_rng(1)
    house = jax.jit(lambda x: jnp.linalg.qr(x)[0])
    chol = jax.jit(cholqr2)
    for m, d, k in (QUICK_ORTH_SHAPES if quick else ORTH_SHAPES):
        X = jnp.asarray(rng.standard_normal((m, d, k)), jnp.float32)
        t_h = _time(house, X)
        t_c = _time(chol, X)
        Q, Qh = chol(X), house(X)
        orth = _orth_err(Q)
        sub = _subspace_err(Q, Qh)
        _row(writer, rows, f"orth/cholqr2/{m}x{d}x{k}", t_c * 1e6,
             householder_us=round(t_h * 1e6, 1),
             speedup=round(t_h / t_c, 2), orth=orth, subspace_vs_qr=sub,
             tol=ORTH_TOL, ok=bool(orth < ORTH_TOL and sub < ORTH_TOL))
        if record:
            key = autotune.record(
                "cholqr", (d, k), X.dtype,
                {"householder": int(t_h < t_c),
                 "us": round(min(t_h, t_c) * 1e6, 1)})
            print(f"[autotune] recorded {key}: "
                  f"{'householder' if t_h < t_c else 'cholqr2'}",
                  file=sys.stderr)


def _step_setup(m, d, k, seed=0):
    from repro.core import ConsensusEngine, erdos_renyi
    from repro.core.operators import StackedOperators
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, d, d)).astype(np.float32) / np.sqrt(d)
    A = (A + A.transpose(0, 2, 1)) / 2
    ops_ = StackedOperators(dense=jnp.asarray(A))
    topo = erdos_renyi(m, p=0.5, seed=seed)
    W0 = jnp.asarray(np.linalg.qr(rng.standard_normal((d, k)))[0],
                     jnp.float32)
    W = jnp.broadcast_to(W0, (m, d, k)).astype(jnp.float32)
    eng = ConsensusEngine(topo, K=1, backend="pallas")    # rounds per call
    return ops_, eng, W0, W


def step_rows(writer, rows, quick: bool) -> bool:
    """Per-stage + end-to-end step path: seed (PR-4) vs fast (PR-5).

    Returns True when every parity check passed.
    """
    from repro.core.step import sign_adjust
    all_ok = True
    for m, d, k, K in (QUICK_STEP_SHAPES if quick else STEP_SHAPES):
        ops_, eng, W0, W = _step_setup(m, d, k)
        S = Gp = W

        apply_fn = jax.jit(ops_.apply)
        mix_track = jax.jit(
            lambda S_, G_, Gp_: eng.mix_track(S_, G_, Gp_, rounds=K))
        house = jax.jit(lambda x: jnp.linalg.qr(x)[0])
        chol = jax.jit(cholqr2)

        @jax.jit
        def step_seed(S_, W_, Gp_):
            G = ops_.apply(W_)
            S2 = eng.mix_track(S_, G, Gp_, rounds=K)
            return S2, sign_adjust(jnp.linalg.qr(S2)[0], W0), G

        @jax.jit
        def step_fast(S_, W_, Gp_):
            S2, G = eng.apply_mix_track(S_, W_, Gp_, ops_, rounds=K)
            return S2, sign_adjust(cholqr2(S2), W0), G

        G = apply_fn(W)
        t_apply = _time(apply_fn, W)
        t_mix = _time(mix_track, S, G, Gp)
        t_house = _time(house, mix_track(S, G, Gp))
        t_chol = _time(chol, mix_track(S, G, Gp))
        t_seed = _time(step_seed, S, W, Gp)
        t_fast = _time(step_fast, S, W, Gp)

        _, Ws, _ = step_seed(S, W, Gp)
        _, Wf, _ = step_fast(S, W, Gp)
        parity = float(jnp.max(jnp.abs(Ws - Wf)))
        ok = parity < PARITY_TOL
        all_ok &= ok
        name = f"step/{m}x{d}x{k}/K{K}"
        _row(writer, rows, f"{name}/apply", t_apply * 1e6)
        _row(writer, rows, f"{name}/mix_track", t_mix * 1e6)
        _row(writer, rows, f"{name}/orth_householder", t_house * 1e6)
        _row(writer, rows, f"{name}/orth_cholqr2", t_chol * 1e6,
             speedup=round(t_house / t_chol, 2))
        _row(writer, rows, f"{name}/full_seed", t_seed * 1e6)
        _row(writer, rows, f"{name}/full_fast", t_fast * 1e6,
             speedup=round(t_seed / t_fast, 2), parity=parity,
             tol=PARITY_TOL, ok=ok)
    return all_ok


def fused_rows(writer, rows, quick: bool) -> bool:
    """apply_mix_track contract rows: poly-fallback bit-equality + kernel
    interpret-mode parity.  Returns True when both hold."""
    from repro.core import ConsensusEngine, erdos_renyi
    from repro.core.operators import StackedOperators
    rng = np.random.default_rng(2)
    m, d, k, K = 8, 48, 3, 5
    A = rng.standard_normal((m, d, d)).astype(np.float32) / np.sqrt(d)
    ops_ = StackedOperators(dense=jnp.asarray((A + A.transpose(0, 2, 1)) / 2))
    topo = erdos_renyi(m, p=0.5, seed=3)
    S, W, Gp = (jnp.asarray(rng.standard_normal((m, d, k)), jnp.float32)
                for _ in range(3))

    # host-independent composition reference (explicit poly fallback)
    from repro.core.mixing import fastmix_eta
    from repro.kernels import fastmix as fm
    L = jnp.asarray(topo.mixing, jnp.float32)
    eta = fastmix_eta(topo.lambda2)
    G_c = ops_.apply(W)
    S_c = fm.fastmix_track_poly(S, G_c, Gp, L, eta, K)

    # poly fallback == explicit composition, bit for bit (acceptance pin).
    # Only meaningful off-TPU: on a TPU host backend="pallas" fires the
    # real apply_track_fused kernel (different summation order), so there
    # the row is skipped rather than asserting a fallback that cannot run.
    ok_bit = True
    if jax.default_backend() != "tpu":
        eng = ConsensusEngine(topo, K=K, backend="pallas")
        S_f, G_f = eng.apply_mix_track(S, W, Gp, ops_)
        bit = float(jnp.max(jnp.abs(S_f - S_c))
                    + jnp.max(jnp.abs(G_f - G_c)))
        ok_bit = bit == 0.0
        _row(writer, rows, "fused/apply_track/poly_bit_equal", 0.0,
             max_abs_diff=bit, tol=0.0, ok=ok_bit)

    # interpret-mode kernel vs the composition (fp32 tolerance)
    engi = ConsensusEngine(topo, K=K, backend="pallas", interpret=True)
    S_k, G_k = engi.apply_mix_track(S, W, Gp, ops_)
    scale = float(jnp.max(jnp.abs(S_c))) + 1.0
    err = max(float(jnp.max(jnp.abs(S_k - S_c))),
              float(jnp.max(jnp.abs(G_k - G_c))))
    ok_kern = err < 2e-5 * scale
    _row(writer, rows, "fused/apply_track/kernel_parity", 0.0,
         max_abs_diff=err, tol=2e-5 * scale, ok=ok_kern)
    return ok_bit and ok_kern


def main(writer=None, quick: bool = False, record: bool = False,
         json_path=None):
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    rows: list = []
    kernel_rows(writer, rows, quick)
    orth_rows(writer, rows, quick, record)
    ok_step = step_rows(writer, rows, quick)
    ok_fused = fused_rows(writer, rows, quick)
    if json_path is not None:      # export BEFORE the parity gate, so a
        from repro.runtime import config as runtime_config
        with open(json_path, "w") as f:    # failing run still ships rows
            json.dump({"bench": "kernels",
                       "device": autotune.device_kind(),
                       "quick": quick, "rows": rows,
                       "config": runtime_config.describe(),
                       "written_at": time.strftime(
                           "%Y-%m-%dT%H:%M:%SZ", time.gmtime())},
                      f, indent=1)
        print(f"\n[json] wrote {json_path}", file=sys.stderr)
    bad = [r["name"] for r in rows if r.get("ok") is False]
    if not (ok_step and ok_fused) or bad:
        raise RuntimeError(f"kernel bench parity rows out of tolerance: {bad}")
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    main(quick="--quick" in argv, record="--record" in argv,
         json_path=json_path)
