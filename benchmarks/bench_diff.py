"""Row-by-row regression diff for the committed bench JSON snapshots.

``benchmarks/run.py --json`` exports ``BENCH_kernels.json`` /
``BENCH_deepca.json`` — the perf-trajectory baselines committed at the
repo root.  This tool compares a fresh export against a committed
baseline and exits nonzero when any metric regressed, so CI gates PRs on
the recorded numbers instead of merely re-measuring them.

Rows are matched by ``name`` (the intersection — a quick-grid export only
diffs the rows it shares with the baseline) and each shared metric is
judged by class:

* **wall-clock** (``us`` — the measured fast-path time): loose *ratio*
  tolerance (default 2.5x — CI runners are noisy; the gate catches
  order-of-magnitude cliffs, not jitter);
* **accuracy** (``parity``, ``orth``, ``subspace_vs_qr``, ``final_tan``,
  ``max_abs_diff``): strict — a candidate value must stay within
  ``acc_ratio`` of the baseline or under the row's own ``tol`` /
  ``acc_floor``, whichever is largest (a convergence break blows these
  up by many orders of magnitude);
* **ok flags**: ``True -> False`` is always a regression (the bench's
  own parity gate started failing); ``False -> True`` is reported as an
  improvement;
* **tolerances**: a row whose ``tol`` *loosened* is a regression —
  widening the goalposts must not sneak past the diff;
* **``rounds``/``programs``/``cold_after_warmup``**: exact — the
  communication-round count is determined by (T, K), the compiled-program
  count by the tenant/request shape mix, and cold-after-warm-up by the
  retrace contract; a drift means the algorithm or the caching contract
  changed, not the machine.
* **wire bytes** (``bytes_per_round``): strict one-sided — any increase
  is a regression (the byte count is a deterministic function of the
  wire dtype and shape, so even +1 byte means the wire contract
  changed); a decrease is an improvement.
* **serving throughput** (``ticks_per_sec``, ``tenant_ticks_per_sec``,
  ``req_per_sec``): one-sided *decrease* gate at the wall-clock ratio —
  higher is better, so only a drop below ``baseline / us_ratio``
  regresses;
* **communication efficiency** (``rounds_per_tick``): one-sided
  *increase* gate at a tight ratio (1.25x) — more gossip rounds per tick
  means the warm-start or escalation policy got less effective, which no
  amount of machine noise explains.

``speedup`` columns are ignored (a ratio of two wall-clocks double-counts
timing noise), and so are the reference-baseline timings (``ref_us``,
``householder_us``, ``v5e_roofline_us``): a slower *oracle* is not a
product regression, and the jnp reference times have been observed to
jitter >10x between runs on one machine.  Baseline rows missing from the
candidate warn by default;
``--require-rows`` promotes them to regressions.  An empty intersection
always fails — a diff that compared nothing must not pass as green.

Importable: :func:`diff` takes two parsed payloads and returns the report
dict; :func:`main` is the CLI (``--report PATH`` writes the report JSON).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

WALLCLOCK_KEYS = ("us",)
ACCURACY_KEYS = ("parity", "orth", "subspace_vs_qr", "final_tan",
                 "max_abs_diff")
EXACT_KEYS = ("rounds", "programs", "cold_after_warmup")
#: Deterministic byte counts: any increase regresses, any decrease improves.
BYTES_KEYS = ("bytes_per_round",)
#: Serving throughput (higher is better): only a *drop* below
#: baseline/us_ratio regresses — gains are improvements, never failures.
THROUGHPUT_KEYS = ("ticks_per_sec", "tenant_ticks_per_sec", "req_per_sec")
#: Communication-efficiency counters (lower is better): an *increase*
#: beyond ROUNDS_RATIO regresses — round counts are policy-determined,
#: not machine-noise-determined, so the gate is tight.
ROUNDS_KEYS = ("rounds_per_tick",)
ROUNDS_RATIO = 1.25

#: Wall-clock ratio gate: candidate/baseline above this fails.
DEFAULT_US_RATIO = 2.5
#: Accuracy ratio gate (baseline-relative) for the strict metric class.
DEFAULT_ACC_RATIO = 10.0
#: Absolute floor under which accuracy metrics never regress — values at
#: 1e-12 jitter by large *ratios* while staying numerically perfect.
DEFAULT_ACC_FLOOR = 1e-6


def load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    if "rows" not in payload or not isinstance(payload["rows"], list):
        raise ValueError(f"{path}: not a bench export (no 'rows' list)")
    return payload


def _index(payload: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    return {r["name"]: r for r in payload["rows"] if "name" in r}


def diff(baseline: Dict[str, Any], candidate: Dict[str, Any], *,
         us_ratio: float = DEFAULT_US_RATIO,
         acc_ratio: float = DEFAULT_ACC_RATIO,
         acc_floor: float = DEFAULT_ACC_FLOOR,
         require_rows: bool = False) -> Dict[str, Any]:
    """Compare ``candidate`` against ``baseline``; see module docstring
    for the per-metric-class rules.  Returns the report dict (``ok`` is
    False iff any regression fired)."""
    regressions: List[str] = []
    warnings: List[str] = []
    improvements: List[str] = []

    for meta in ("bench", "device", "quick"):
        a, b = baseline.get(meta), candidate.get(meta)
        if a != b:
            warnings.append(f"{meta} mismatch: baseline={a!r} "
                            f"candidate={b!r}")

    base = _index(baseline)
    cand = _index(candidate)
    missing = sorted(set(base) - set(cand))
    new = sorted(set(cand) - set(base))
    for name in missing:
        msg = f"row missing from candidate: {name}"
        (regressions if require_rows else warnings).append(msg)
    if new:
        warnings.append(f"{len(new)} rows only in candidate "
                        f"(new benches): {', '.join(new[:5])}"
                        + (" ..." if len(new) > 5 else ""))

    shared = sorted(set(base) & set(cand))
    compared = 0
    for name in shared:
        a, b = base[name], cand[name]
        compared += 1

        if a.get("ok") is True and b.get("ok") is False:
            regressions.append(f"{name}: ok True -> False "
                               "(bench parity gate now failing)")
        elif a.get("ok") is False and b.get("ok") is True:
            improvements.append(f"{name}: ok False -> True")

        if "tol" in a and "tol" in b and float(b["tol"]) > float(a["tol"]):
            regressions.append(
                f"{name}: tol loosened {a['tol']:g} -> {b['tol']:g}")

        for key in WALLCLOCK_KEYS:
            if key not in a or key not in b:
                continue
            va, vb = float(a[key]), float(b[key])
            if va <= 0.0:
                continue
            ratio = vb / va
            if ratio > us_ratio:
                regressions.append(
                    f"{name}: {key} {va:g} -> {vb:g} "
                    f"({ratio:.2f}x > {us_ratio:g}x gate)")
            elif ratio < 1.0 / us_ratio:
                improvements.append(
                    f"{name}: {key} {va:g} -> {vb:g} ({ratio:.2f}x)")

        for key in ACCURACY_KEYS:
            if key not in a or key not in b:
                continue
            va, vb = float(a[key]), float(b[key])
            floor = max(acc_floor, float(a.get("tol", 0.0)))
            allowed = max(va * acc_ratio, floor)
            if vb > allowed:
                regressions.append(
                    f"{name}: {key} {va:.3e} -> {vb:.3e} "
                    f"(allowed <= {allowed:.3e})")
            elif va > floor and vb < va / acc_ratio:
                improvements.append(
                    f"{name}: {key} {va:.3e} -> {vb:.3e}")

        for key in EXACT_KEYS:
            if key in a and key in b and float(a[key]) != float(b[key]):
                regressions.append(
                    f"{name}: {key} changed {a[key]:g} -> {b[key]:g} "
                    "(must match exactly)")

        for key in THROUGHPUT_KEYS:
            if key not in a or key not in b:
                continue
            va, vb = float(a[key]), float(b[key])
            if va <= 0.0:
                continue
            ratio = vb / va
            if ratio < 1.0 / us_ratio:
                regressions.append(
                    f"{name}: {key} dropped {va:g} -> {vb:g} "
                    f"({ratio:.2f}x < 1/{us_ratio:g} gate)")
            elif ratio > us_ratio:
                improvements.append(
                    f"{name}: {key} {va:g} -> {vb:g} ({ratio:.2f}x)")

        for key in ROUNDS_KEYS:
            if key not in a or key not in b:
                continue
            va, vb = float(a[key]), float(b[key])
            if va <= 0.0:
                continue
            if vb > va * ROUNDS_RATIO:
                regressions.append(
                    f"{name}: {key} grew {va:g} -> {vb:g} "
                    f"(> {ROUNDS_RATIO:g}x gate — policy efficiency, "
                    "not machine noise)")
            elif vb < va / ROUNDS_RATIO:
                improvements.append(f"{name}: {key} {va:g} -> {vb:g}")

        for key in BYTES_KEYS:
            if key not in a or key not in b:
                continue
            va, vb = float(a[key]), float(b[key])
            if vb > va:
                regressions.append(
                    f"{name}: {key} grew {va:g} -> {vb:g} B "
                    "(wire bytes are deterministic; any increase is a "
                    "contract change)")
            elif vb < va:
                improvements.append(
                    f"{name}: {key} {va:g} -> {vb:g} B")

    if compared == 0:
        regressions.append(
            "no comparable rows: baseline/candidate names are disjoint "
            f"({len(base)} vs {len(cand)} rows) — a vacuous diff is not "
            "a pass")

    return {
        "baseline": {k: baseline.get(k)
                     for k in ("bench", "device", "quick", "written_at")},
        "candidate": {k: candidate.get(k)
                      for k in ("bench", "device", "quick", "written_at")},
        "compared": compared,
        "regressions": regressions,
        "warnings": warnings,
        "improvements": improvements,
        "ok": not regressions,
    }


def render(report: Dict[str, Any]) -> str:
    lines = [f"bench_diff: compared {report['compared']} shared rows "
             f"({report['baseline'].get('bench')})"]
    for label, items in (("REGRESSION", report["regressions"]),
                         ("warning", report["warnings"]),
                         ("improved", report["improvements"])):
        for msg in items:
            lines.append(f"  [{label}] {msg}")
    lines.append("RESULT: " + ("OK" if report["ok"] else "REGRESSED"))
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="diff two bench JSON exports; nonzero exit on "
                    "regression")
    p.add_argument("baseline", help="committed snapshot (the reference)")
    p.add_argument("candidate", help="fresh export to judge")
    p.add_argument("--us-ratio", type=float, default=DEFAULT_US_RATIO,
                   help="wall-clock ratio gate (default %(default)s)")
    p.add_argument("--acc-ratio", type=float, default=DEFAULT_ACC_RATIO,
                   help="accuracy ratio gate (default %(default)s)")
    p.add_argument("--acc-floor", type=float, default=DEFAULT_ACC_FLOOR,
                   help="absolute accuracy floor (default %(default)s)")
    p.add_argument("--require-rows", action="store_true",
                   help="baseline rows missing from the candidate fail "
                        "instead of warning")
    p.add_argument("--report", metavar="PATH",
                   help="also write the report dict as JSON")
    args = p.parse_args(argv)

    report = diff(load(args.baseline), load(args.candidate),
                  us_ratio=args.us_ratio, acc_ratio=args.acc_ratio,
                  acc_floor=args.acc_floor, require_rows=args.require_rows)
    print(render(report))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[json] wrote {args.report}", file=sys.stderr)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
