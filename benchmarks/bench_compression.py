"""DeEPCA-PowerSGD gradient compression: bytes-on-wire vs dense all-reduce,
and quality (consensus + accumulated-gradient fidelity) per (rank, K)."""
from __future__ import annotations

import csv
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.compression import DeEPCACompressor
from repro.core import erdos_renyi, torus2d


def main(writer=None) -> None:
    own = writer is None
    if own:
        writer = csv.writer(sys.stdout)
        writer.writerow(["name", "us_per_call", "derived"])
    m = 16
    topo = torus2d(4, 4)
    rng = np.random.default_rng(0)
    shape = (1024, 768)      # LM-layer scale; wire ratio ~ min(d)/(K*deg*r)
    base = rng.standard_normal((shape[0], 8)) @ rng.standard_normal(
        (8, shape[1])) / 8
    grads = {"w": jnp.asarray(
        base[None] + 0.1 * rng.standard_normal((m,) + shape), jnp.float32)}

    for rank in (8, 32):
        for K in (4, 8):
            comp = DeEPCACompressor(topology=topo, rank=rank, K=K, min_dim=8)
            state = comp.init(grads)
            acc_hat = jnp.zeros(shape)
            acc_true = jnp.zeros(shape)
            t0 = time.perf_counter()
            steps = 20
            for _ in range(steps):
                out, state = comp(grads, state)
                acc_hat = acc_hat + out["w"][0]
                acc_true = acc_true + jnp.mean(grads["w"], axis=0)
            dt = (time.perf_counter() - t0) / steps
            fid = float(jnp.linalg.norm(acc_hat - acc_true)
                        / jnp.linalg.norm(acc_true))
            rep = comp.bytes_per_step(grads)
            writer.writerow([
                f"compression/r{rank}_K{K}", f"{dt * 1e6:.1f}",
                f"acc_err={fid:.3e};wire_ratio={rep['ratio']:.1f};"
                f"gossip_bytes={rep['deepca_gossip']}"])


if __name__ == "__main__":
    main()
