import io, re, subprocess, sys
def table(mesh):
    out = subprocess.run([sys.executable, "-m", "benchmarks.roofline_report",
                          "--out", "results/dryrun_final", "--mesh", mesh],
                         capture_output=True, text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    return out.stdout
import os
os.environ.setdefault("PYTHONPATH", "src")
single = subprocess.run([sys.executable, "-m", "benchmarks.roofline_report",
                         "--out", "results/dryrun_final", "--mesh", "single"],
                        capture_output=True, text=True).stdout
multi = subprocess.run([sys.executable, "-m", "benchmarks.roofline_report",
                        "--out", "results/dryrun_final", "--mesh", "multi"],
                       capture_output=True, text=True).stdout
txt = open("EXPERIMENTS.md").read()
txt = txt.replace("<!-- ROOFLINE_TABLE_SINGLE -->", single)
txt = txt.replace("<!-- ROOFLINE_TABLE_MULTI -->", multi)
open("EXPERIMENTS.md", "w").write(txt)
print("injected", len(single.splitlines()), len(multi.splitlines()))
